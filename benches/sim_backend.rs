//! Bench: the native crossbar-simulator hot paths — exact-f32 forward,
//! bit-serial integer forward, the faithful phase loop (packed bit-planes
//! vs. scalar lane scan, with ADC + conductance noise), and the sharded
//! serving engine at 1/2/4/8 workers. Fully hermetic (no artifacts), so
//! this is the one bench that runs on a fresh clone:
//!
//!     cargo bench --bench sim_backend
//!
//! Every measurement is also emitted to `BENCH_sim_backend.json` (see
//! `util::bench`) — CI's `bench-smoke` job runs this in quick mode
//! (`BENCH_QUICK=1`), uploads the JSON, and gates the means against
//! `benches/baseline.json`.

use reram_mpq::backend::{ExecBackend, FwdKind, SimXbar, SimXbarConfig, StripPrecision};
use reram_mpq::coordinator::{BackendSpec, Engine, EngineConfig};
use reram_mpq::quant::{self, BitMap};
use reram_mpq::tensor::Tensor;
use reram_mpq::util::bench::Bench;
use reram_mpq::{fixture, RunConfig};

fn main() {
    let bench = Bench::from_env();
    let fx = fixture::tiny(1);
    let model = &fx.model;
    let theta_t = Tensor::from_vec(fx.theta.clone());
    let xb = fx.test.x.slice_rows(0, model.entry.batch.eval);

    // 1. exact f32 native forward (fp32 reference deployments)
    let exact = SimXbar::new(SimXbarConfig::default());
    bench.run("sim exact-f32 forward (tiny, batch 4)", || {
        exact.forward(model, FwdKind::Eval, &theta_t, &xb).expect("forward")
    });

    // 2. bit-serial integer forward on mixed 4/8-bit strips (the serving
    // fast path: ideal converters)
    let mut cfg = RunConfig::default();
    cfg.quant.device_sigma = 0.0;
    let bits: Vec<u8> = (0..model.num_strips())
        .map(|i| if i % 2 == 0 { 8 } else { 4 })
        .collect();
    let qm = quant::apply(model, &fx.theta, &BitMap { bits }, &cfg.quant);
    let qtheta_t = Tensor::from_vec(qm.theta.clone());
    let sim = SimXbar::from_quantized(SimXbarConfig::default(), &qm);
    bench.run("sim bit-serial forward, ideal ADC (tiny, batch 4)", || {
        sim.forward(model, FwdKind::Eval, &qtheta_t, &xb).expect("forward")
    });

    // 3. the faithful phase loop with a 4-bit ADC and conductance noise —
    // one image, since every input-bit phase converts separately
    let noisy = SimXbar::new(SimXbarConfig::default().with_adc(4).with_noise(0.1, 3))
        .with_strips(StripPrecision::from_quantized(&qm));
    let x1 = fx.test.x.slice_rows(0, 1);
    bench.run("sim phase-loop forward, 4b ADC + noise (1 image)", || {
        noisy.forward(model, FwdKind::Eval, &qtheta_t, &x1).expect("forward")
    });

    // 4. packed bit-planes vs scalar lane scan: the same noise-free 4-bit
    // ADC phase loop, once through the u64 popcount path and once through
    // the per-lane reference — bit-identical outputs, different speed.
    // Single-threaded so the packing speedup is isolated from sharding.
    let adc_cfg = SimXbarConfig::default().with_adc(4).with_threads(1);
    let packed = SimXbar::new(adc_cfg).with_strips(StripPrecision::from_quantized(&qm));
    bench.run("sim phase-loop 4b ADC, packed bit-planes (1 image)", || {
        packed.forward(model, FwdKind::Eval, &qtheta_t, &x1).expect("forward")
    });
    let scalar = SimXbar::new(SimXbarConfig { scalar_lanes: true, ..adc_cfg })
        .with_strips(StripPrecision::from_quantized(&qm));
    bench.run("sim phase-loop 4b ADC, scalar lanes (1 image)", || {
        scalar.forward(model, FwdKind::Eval, &qtheta_t, &x1).expect("forward")
    });

    // 5. sharded-engine throughput: 32 requests through the dynamic batcher
    // at 1/2/4/8 backend workers. The simulator pins threads=1 so the
    // engine-level sharding is what scales (not the per-conv tile shards).
    let elems = 32 * 32 * 3;
    let images: Vec<Vec<f32>> = (0..32)
        .map(|j| {
            let s = (j % fx.test.len()) * elems;
            fx.test.x.data()[s..s + elems].to_vec()
        })
        .collect();
    for workers in [1usize, 2, 4, 8] {
        let spec = BackendSpec::Sim {
            cfg: SimXbarConfig::default().with_threads(1),
            strips: Some(StripPrecision::from_quantized(&qm)),
            scenario: None,
        };
        let engine = Engine::new(
            spec,
            model,
            qm.theta.clone(),
            EngineConfig::default().with_workers(workers),
        )
        .expect("engine");
        let handle = engine.start().expect("engine start");
        // warm the batcher once outside the timer
        let _ = handle.classify(images[0].clone()).expect("warmup");
        bench.run(
            &format!("sim engine throughput, {workers} worker(s), 32 reqs"),
            || {
                let pendings: Vec<_> = images
                    .iter()
                    .map(|img| handle.submit(img.clone()).expect("submit"))
                    .collect();
                for p in pendings {
                    p.wait().expect("reply");
                }
            },
        );
    }

    bench.emit_json("sim_backend").expect("bench json");
}
