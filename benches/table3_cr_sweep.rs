//! Bench: regenerate paper Table 3 (compression-ratio sweep with energy
//! breakdown) and time the sweep under the staged plan API.
//!
//!     cargo bench --bench table3_cr_sweep

mod common;

use reram_mpq::experiments::{self, Lab};
use reram_mpq::util::bench::Bench;
use reram_mpq::RunConfig;

fn main() {
    let c = common::ctx();
    let cfg = RunConfig::default();
    let opts = common::opts();
    let lab = Lab::new(&c.runtime, &c.manifest, cfg);

    let mut rows = None;
    Bench::from_env().run("table3: CR sweep 0..100% (resnet8)", || {
        rows = Some(
            experiments::table3(&lab, opts, experiments::TABLE3_CRS).expect("table3"),
        );
    });
    let rows = rows.unwrap();
    println!();
    println!("{}", experiments::render_table3(&rows));

    // Shape assertions: energy decreases monotonically with CR and the ADC
    // component dominates (the paper's §5.3 observations).
    for w in rows.windows(2) {
        assert!(
            w[1].cost.energy.system_mj() <= w[0].cost.energy.system_mj() + 1e-9,
            "energy must fall as CR rises"
        );
    }
    let r0 = &rows[0];
    assert!(r0.cost.energy.adc_mj / r0.cost.energy.system_mj() > 0.8, "ADC dominates");
}
