//! Bench: program-once crossbars — the programmed tile walk vs. the
//! re-quantize-and-repack-per-call reference path, plus the one-time
//! programming cost itself. The 4b-ADC programmed walk is measured twice:
//! once pinned to the scalar packed-u64 kernel (`SimdMode::Off`) and once
//! with runtime-detected SIMD (`SimdMode::Auto`), so the SIMD speedup is
//! its own gated row. Fully hermetic (in-memory fixture, no AOT
//! artifacts):
//!
//!     cargo bench --bench xbar_programmed
//!
//! Emits `BENCH_xbar_programmed.json`; the program-once row carries a
//! `planes_bytes` annotation (bytes of programmed weight-side storage) and
//! a `live_strips` count, so the perf pipeline sees the artifact size next
//! to the speedup. CI's `bench-smoke` runs this in quick mode and gates it
//! against `benches/baseline.json`.

use reram_mpq::backend::{ProgrammedModel, SimXbar, SimXbarConfig, SimdMode, StripPrecision};
use reram_mpq::quant::{self, BitMap};
use reram_mpq::util::bench::Bench;
use reram_mpq::util::rng::Rng;
use reram_mpq::{fixture, RunConfig};

fn main() {
    let b = Bench::from_env();
    let fx = fixture::tiny(1);
    let model = &fx.model;
    let mut cfg = RunConfig::default();
    cfg.quant.device_sigma = 0.0;
    let bits: Vec<u8> = (0..model.num_strips())
        .map(|i| if i % 2 == 0 { 8 } else { 4 })
        .collect();
    let qm = quant::apply(model, &fx.theta, &BitMap { bits }, &cfg.quant);
    let sp = StripPrecision::from_quantized(&qm);

    // 1. the one-time programming cost (all conv layers) + artifact size
    let scfg = SimXbarConfig::default().with_threads(1);
    let mut planes_bytes = 0.0f64;
    let mut live_strips = 0.0f64;
    b.run("xbar program-once (tiny, all layers)", || {
        let p = ProgrammedModel::program(model, &qm.theta, &sp, &scfg).expect("program");
        planes_bytes = p.planes_bytes as f64;
        live_strips = p.live_strips as f64;
        p
    });
    b.annotate(
        "xbar program-once (tiny, all layers)",
        &[("planes_bytes", planes_bytes), ("live_strips", live_strips)],
    );

    // The widest conv layer (largest K²·D), synthetic patches.
    let layer = model
        .conv_layers()
        .iter()
        .max_by_key(|l| l.k * l.k * l.d)
        .expect("fixture has conv layers")
        .clone();
    let mut rng = Rng::seed_from_u64(7);
    let t = 16usize;
    let patches: Vec<f32> =
        (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();

    // 2. ideal-ADC (exact integer) mode: programmed walk vs re-pack-per-call
    let ideal = SimXbar::new(scfg);
    // warm once so the cached artifact exists before the timer
    let _ = ideal
        .conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
        .expect("conv");
    b.run("xbar programmed conv, ideal ADC (tiny widest layer)", || {
        ideal
            .conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
            .expect("conv")
    });
    b.run("xbar re-pack-per-call conv, ideal ADC (tiny widest layer)", || {
        ideal
            .conv_bitserial_reference(model, &layer, &qm.theta, &patches, t, &sp)
            .expect("conv")
    });

    // 3. faithful 4-bit-ADC packed phase loop: same comparison. The
    //    programmed row is pinned to SimdMode::Off so it stays the scalar
    //    packed-u64 walk — the reference point the SIMD row below is
    //    measured against.
    let adc = SimXbar::new(scfg.with_adc(4).with_simd(SimdMode::Off));
    let _ = adc
        .conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
        .expect("conv");
    b.run("xbar programmed conv, 4b ADC packed (tiny widest layer)", || {
        adc.conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
            .expect("conv")
    });
    b.run("xbar re-pack-per-call conv, 4b ADC packed (tiny widest layer)", || {
        adc.conv_bitserial_reference(model, &layer, &qm.theta, &patches, t, &sp)
            .expect("conv")
    });

    // 4. the SIMD-widened walk (runtime-detected AVX2/NEON, scalar where
    //    neither exists) over the same programmed artifact.
    let adc_simd = SimXbar::new(scfg.with_adc(4).with_simd(SimdMode::Auto));
    let _ = adc_simd
        .conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
        .expect("conv");
    b.run("xbar programmed conv, 4b ADC SIMD (tiny widest layer)", || {
        adc_simd
            .conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
            .expect("conv")
    });

    // Speedup summary for the console (the JSON carries the raw means).
    let ms = b.measurements();
    let mean = |name: &str| {
        ms.iter()
            .find(|m| m.name == name)
            .map(|m| m.mean.as_secs_f64())
    };
    if let (Some(p), Some(r)) = (
        mean("xbar programmed conv, ideal ADC (tiny widest layer)"),
        mean("xbar re-pack-per-call conv, ideal ADC (tiny widest layer)"),
    ) {
        if p > 0.0 {
            println!("  ideal-ADC programmed speedup: {:.2}x", r / p);
        }
    }
    if let (Some(p), Some(r)) = (
        mean("xbar programmed conv, 4b ADC packed (tiny widest layer)"),
        mean("xbar re-pack-per-call conv, 4b ADC packed (tiny widest layer)"),
    ) {
        if p > 0.0 {
            println!("  4b-ADC packed programmed speedup: {:.2}x", r / p);
        }
    }
    if let (Some(s), Some(p)) = (
        mean("xbar programmed conv, 4b ADC SIMD (tiny widest layer)"),
        mean("xbar programmed conv, 4b ADC packed (tiny widest layer)"),
    ) {
        if s > 0.0 {
            println!(
                "  4b-ADC SIMD walk ({}): {:.2}x over scalar packed",
                adc_simd.simd_kernel_name(),
                p / s
            );
        }
    }
    println!(
        "  artifact: {:.0} bytes programmed weight-side storage, {:.0} live strips",
        planes_bytes, live_strips
    );

    b.emit_json("xbar_programmed").expect("bench json");
}
