//! Bench: regenerate paper Table 2 (HAP vs OURS, ResNet20 @74% CR) and time
//! the staged plan. Iterations after the first hit the shared stage cache —
//! the steady-state cost of re-running a table under the builder API.
//!
//!     cargo bench --bench table2_hap_vs_ours

mod common;

use reram_mpq::experiments::{self, Lab};
use reram_mpq::util::bench::Bench;
use reram_mpq::RunConfig;

fn main() {
    let c = common::ctx();
    let cfg = RunConfig::default();
    let opts = common::opts();
    let lab = Lab::new(&c.runtime, &c.manifest, cfg);

    let mut last = None;
    Bench::from_env().run("table2: HAP vs OURS (resnet20 @74% CR)", || {
        last = Some(experiments::table2(&lab, opts).expect("table2"));
    });
    let t = last.unwrap();
    println!();
    println!("{}", experiments::render_table2(&t));

    // Shape assertions mirroring the paper's claims: OURS keeps more
    // accuracy and costs less than HAP at the same CR.
    assert!(
        t.ours.accuracy.top1 >= t.hap.accuracy.top1,
        "OURS top-1 should beat HAP"
    );
    assert!(
        t.ours.cost.energy.system_mj() < t.hap.cost.energy.system_mj(),
        "OURS energy should beat HAP"
    );
    assert!(
        t.ours.cost.latency_ms < t.hap.cost.latency_ms,
        "OURS latency should beat HAP"
    );
}
