//! Bench: tracing overhead — the programmed crossbar walk and a serve
//! round-trip, each measured with the span recorder off (the default) and
//! on. The trace-off rows are the gated ones: tracing is compiled in
//! everywhere, so its disabled guards sit on the hot path of every build,
//! and the `baseline.json` entry for the walk carries `max_regress 0.02`
//! (the default-off path may cost at most 2%). Fully hermetic:
//!
//!     cargo bench --bench trace_overhead
//!
//! Emits `BENCH_trace_overhead.json`; each trace-on record carries an
//! `overhead_frac` annotation ((on − off) / off mean) so the perf pipeline
//! sees the enabled cost as a ratio, not just absolute nanoseconds.

use std::net::TcpListener;
use std::time::Duration;

use reram_mpq::backend::{SimXbar, SimXbarConfig, StripPrecision};
use reram_mpq::coordinator::{CompressionPlan, EngineConfig, Executor, ModelState};
use reram_mpq::quant::{self, BitMap};
use reram_mpq::serve::{BatchPolicy, ServeClient, ServeConfig, Server};
use reram_mpq::util::bench::Bench;
use reram_mpq::util::rng::Rng;
use reram_mpq::{fixture, trace, RunConfig};

const WALK_OFF: &str = "xbar programmed walk, trace off (tiny widest layer)";
const WALK_ON: &str = "xbar programmed walk, trace on (tiny widest layer)";
const SERVE_OFF: &str = "serve round-trip, trace off (tcp loopback)";
const SERVE_ON: &str = "serve round-trip, trace on (tcp loopback)";

fn main() -> reram_mpq::Result<()> {
    let b = Bench::from_env();

    // --- programmed 4b-ADC packed walk (same workload as xbar_programmed)
    let fx = fixture::tiny(1);
    let model = &fx.model;
    let mut cfg = RunConfig::default();
    cfg.quant.device_sigma = 0.0;
    let bits: Vec<u8> = (0..model.num_strips())
        .map(|i| if i % 2 == 0 { 8 } else { 4 })
        .collect();
    let qm = quant::apply(model, &fx.theta, &BitMap { bits }, &cfg.quant);
    let sp = StripPrecision::from_quantized(&qm);
    let layer = model
        .conv_layers()
        .iter()
        .max_by_key(|l| l.k * l.k * l.d)
        .expect("fixture has conv layers")
        .clone();
    let mut rng = Rng::seed_from_u64(7);
    let t = 16usize;
    let patches: Vec<f32> =
        (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();

    let sim = SimXbar::new(SimXbarConfig::default().with_threads(1).with_adc(4));
    let _ = sim
        .conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
        .expect("conv");

    // Trace-off rows run FIRST: `trace::enable()` is process-global and the
    // off rows must measure the never-enabled fast path (one relaxed load).
    b.run(WALK_OFF, || {
        sim.conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
            .expect("conv")
    });

    trace::enable();
    b.run(WALK_ON, || {
        let out = sim
            .conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
            .expect("conv");
        // Keep the recorder's buffers bounded so the row measures span
        // capture, not an ever-growing drain backlog.
        trace::flush_thread();
        let _ = trace::drain();
        out
    });
    trace::disable();
    let _ = trace::drain();

    // --- serve round-trip over TCP loopback (1 connection, small batch)
    let fx = fixture::tiny(5);
    let elems = 32 * 32 * 3;
    let image = fx.test.x.data()[..elems].to_vec();
    let plan = CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(SimXbarConfig::default()),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        RunConfig::default(),
    );
    let handle = plan.deploy_fp32(EngineConfig::default().with_workers(2))?;
    let server = Server::start(
        TcpListener::bind("127.0.0.1:0")?,
        handle,
        ServeConfig {
            policy: BatchPolicy {
                max_batch: 8,
                flush_after: Duration::from_millis(1),
                queue: 512,
            },
            ..ServeConfig::default()
        },
    )?;
    let addr = server.local_addr().to_string();
    let mut client = ServeClient::connect(&addr)?;
    let _ = client.classify(image.clone())?; // warm the connection + engine

    b.run(SERVE_OFF, || client.classify(image.clone()).expect("classify"));

    trace::enable();
    b.run(SERVE_ON, || {
        let r = client.classify(image.clone()).expect("classify");
        let _ = trace::drain();
        r
    });
    trace::disable();
    let _ = trace::drain();

    // Overhead ratios for the JSON + console.
    let ms = b.measurements();
    let mean = |name: &str| ms.iter().find(|m| m.name == name).map(|m| m.mean.as_secs_f64());
    for (off, on) in [(WALK_OFF, WALK_ON), (SERVE_OFF, SERVE_ON)] {
        if let (Some(off_s), Some(on_s)) = (mean(off), mean(on)) {
            if off_s > 0.0 {
                let frac = (on_s - off_s) / off_s;
                b.annotate(on, &[("overhead_frac", frac)]);
                println!("  {on}: {:+.2}% vs trace off", frac * 100.0);
            }
        }
    }

    b.emit_json("trace_overhead")?;
    Ok(())
}
