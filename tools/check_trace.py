#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file emitted by `reram-mpq`.

Checks (stdlib only, exits non-zero with a message on the first failure):

  1. The file parses as JSON and has the object form
     {"traceEvents": [...], ...} that ui.perfetto.dev / chrome://tracing
     load.
  2. Every event carries the required fields (name, ph, ts, pid, tid),
     ph is "B" or "E", and ts is a non-negative number.
  3. Per (pid, tid), B/E events balance like a bracket string: every E
     closes the most recent open B of the same name, and nothing stays
     open at the end (the recorder's RAII spans guarantee this).
  4. Optionally (--require NAME...), each NAME matches at least one span
     name; a trailing ':' does prefix matching, so `--require layer:`
     asserts at least one per-layer forward span exists.

Usage:
  python3 tools/check_trace.py serve_trace.json \
      --require server.handle batcher.submit backend.forward layer:
"""

import argparse
import json
import sys


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument(
        "--require",
        nargs="*",
        default=[],
        metavar="NAME",
        help="span names that must appear; a trailing ':' prefix-matches",
    )
    ap.add_argument(
        "--min-events",
        type=int,
        default=1,
        help="minimum number of events expected (default 1)",
    )
    args = ap.parse_args()

    try:
        with open(args.trace, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{args.trace}: {e}")

    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail("top level must be an object with a 'traceEvents' array")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        fail("'traceEvents' is not an array")
    if len(events) < args.min_events:
        fail(f"only {len(events)} event(s), expected >= {args.min_events}")

    names = set()
    stacks = {}  # (pid, tid) -> [open span names]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"event #{i} is not an object")
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                fail(f"event #{i} missing '{field}': {ev}")
        name, ph, ts = ev["name"], ev["ph"], ev["ts"]
        if ph not in ("B", "E"):
            fail(f"event #{i} has ph={ph!r}, expected 'B' or 'E'")
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(f"event #{i} has non-numeric or negative ts: {ts!r}")
        names.add(name)
        key = (ev["pid"], ev["tid"])
        stack = stacks.setdefault(key, [])
        if ph == "B":
            stack.append(name)
        else:
            if not stack:
                fail(f"event #{i}: E {name!r} on tid {key[1]} with no open span")
            top = stack.pop()
            if top != name:
                fail(
                    f"event #{i}: E {name!r} on tid {key[1]} closes "
                    f"open span {top!r} (misnested)"
                )

    for (pid, tid), stack in stacks.items():
        if stack:
            fail(f"tid {tid} (pid {pid}) ends with unclosed span(s): {stack}")

    for want in args.require:
        if want.endswith(":"):
            ok = any(n.startswith(want) for n in names)
        else:
            ok = want in names
        if not ok:
            fail(f"required span {want!r} never appears (saw: {sorted(names)})")

    tids = len(stacks)
    print(
        f"check_trace: OK: {len(events)} events, {len(names)} span name(s), "
        f"{tids} thread(s), all B/E balanced"
    )


if __name__ == "__main__":
    main()
