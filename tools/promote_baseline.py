#!/usr/bin/env python3
"""Promote a CI-refreshed perf baseline into `benches/baseline.json`.

CI's bench-smoke job refreshes a copy of the committed baseline with the
runner's own means (`check_regression.py --update`) and uploads it as the
`baseline.refreshed.json` artifact. This tool closes ROADMAP item 4's loop:
it takes that artifact and produces a committable `benches/baseline.json`
whose **means** come from the trusted run while every piece of gate
**policy** — the `note`, the global `tolerance`, and each row's pinned
`max_regress` override — is re-asserted from the committed file, so a run
can never loosen the gate by shipping a doctored artifact.

Promotion is strict:
  * every committed row must appear in the refreshed file with a positive,
    finite `mean_ns` (a bootstrap or missing row means the trusted run did
    not actually measure the full pipeline — refuse to promote);
  * rows the refreshed file adds on top of the committed set are carried
    over as new gated rows (with a note on stdout), since `--update`
    appends newly added benches the same way.

Usage:
    # validate + write the promoted baseline next to the artifact
    python3 tools/promote_baseline.py baseline.refreshed.json \
        --into benches/baseline.json --out baseline.promoted.json

    # maintainer loop: download the bench-json artifact from a trusted run,
    # then promote straight over the committed file and commit the diff
    python3 tools/promote_baseline.py baseline.refreshed.json

    # CI dry-run: validate the artifact is promotable, write nothing
    python3 tools/promote_baseline.py baseline.refreshed.json --check

Exit status: 0 when the refreshed file is promotable (and, without
--check, the output was written), 1 otherwise. Stdlib only — runs on a
bare CI runner.
"""

import argparse
import json
import math
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("refreshed", help="baseline.refreshed.json from a trusted run")
    ap.add_argument(
        "--into",
        default="benches/baseline.json",
        help="committed baseline supplying the gate policy (default: %(default)s)",
    )
    ap.add_argument(
        "--out",
        default=None,
        help="where to write the promoted baseline (default: overwrite --into)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="validate only — exit 0 if promotable, write nothing",
    )
    args = ap.parse_args()

    committed = load(args.into)
    refreshed = load(args.refreshed)
    fresh = {r["name"]: r for r in refreshed.get("results", [])}

    errors = []
    promoted_rows = []
    for rec in committed.get("results", []):
        name = rec["name"]
        if name not in fresh:
            errors.append(f"committed row '{name}' missing from the refreshed file")
            continue
        mean = fresh[name].get("mean_ns")
        if not isinstance(mean, (int, float)) or not math.isfinite(mean) or mean <= 0:
            errors.append(f"committed row '{name}' has no usable mean ({mean!r})")
            continue
        # Mean from the trusted run; everything else (max_regress pin
        # included) from the committed policy row.
        row = dict(rec)
        row["mean_ns"] = float(mean)
        promoted_rows.append(row)

    committed_names = {r["name"] for r in committed.get("results", [])}
    added = 0
    for name in sorted(set(fresh) - committed_names):
        mean = fresh[name].get("mean_ns")
        if not isinstance(mean, (int, float)) or not math.isfinite(mean) or mean <= 0:
            # A new bench that the trusted run itself never measured gates
            # nothing — leave it for a future refresh rather than pinning
            # a null row.
            print(f"note: new row '{name}' has no usable mean; skipped")
            continue
        print(f"note: new row '{name}' promoted from the refreshed file")
        promoted_rows.append({"name": name, "mean_ns": float(mean)})
        added += 1

    if errors:
        for e in errors:
            print(f"::error::{e}", file=sys.stderr)
        print(
            f"not promotable: {len(errors)} of {len(committed_names)} committed "
            "rows lack a trusted mean",
            file=sys.stderr,
        )
        return 1

    pinned = sum(1 for r in promoted_rows if "max_regress" in r)
    print(
        f"promotable: {len(promoted_rows)} rows ({pinned} with pinned "
        f"max_regress, {added} new), policy from {args.into}"
    )
    if args.check:
        return 0

    out = dict(committed)  # note + tolerance from the committed policy
    out["results"] = promoted_rows
    out_path = args.out or args.into
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"promoted baseline written: {out_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
